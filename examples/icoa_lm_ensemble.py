"""ICOA over transformer agents — the paper's technique on the LM substrate
(DESIGN.md §4.1 applicability bridge).

Attribute-distributed sequence regression: every agent sees the SAME token
sequences but only its own stratum (positions == i mod D are visible, the
rest are masked) — a vertical partition of the sequence "attributes". The
outcome mixes all strata nonlinearly (a Friedman-1 composite over per-stratum
statistics), so no single agent can fit it alone. Agents are tiny
transformer regressors (H_i = {1-layer transformer + pooled head}); the
ICOA projection step is a warm-started Adam refit with f_hat as the target.

    PYTHONPATH=src python examples/icoa_lm_ensemble.py
"""
import dataclasses
import time
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, icoa
from repro.models import layers as L

VOCAB, SEQ, D_AGENTS, DM = 64, 32, 4, 32
MASK_TOK = VOCAB  # reserved mask id


# ---------------------------------------------------------------- the task


def make_data(n: int, seed: int):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, VOCAB, size=(n, SEQ)).astype(np.int32)
    # per-stratum statistic: mean token value of stratum j, scaled to [0,1]
    stats = np.stack([toks[:, j::D_AGENTS].mean(axis=1) / VOCAB
                      for j in range(D_AGENTS)], axis=1)
    y = (10 * np.sin(np.pi * stats[:, 0] * stats[:, 1])
         + 20 * (stats[:, 2] - 0.5) ** 2 + 10 * stats[:, 3])
    y = (y - y.min()) / (y.max() - y.min())
    views = []
    for i in range(D_AGENTS):
        v = np.full_like(toks, MASK_TOK)
        v[:, i::D_AGENTS] = toks[:, i::D_AGENTS]   # agent i's visible stratum
        views.append(v)
    return jnp.asarray(np.stack(views)), jnp.asarray(y.astype(np.float32))


# ------------------------------------------------- transformer agent family


@dataclasses.dataclass(frozen=True)
class TransformerRegressorFamily:
    n_cols: int = SEQ           # (kept for API symmetry; input is tokens)
    fit_steps: int = 60
    lr: float = 3e-3

    def init(self, key) -> dict:
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {
            "emb": jax.random.normal(k1, (VOCAB + 1, DM)) * 0.05,
            "wq": L.dense_init(k2, (DM, DM), jnp.float32),
            "wk": L.dense_init(k3, (DM, DM), jnp.float32),
            "wv": L.dense_init(jax.random.fold_in(k3, 1), (DM, DM), jnp.float32),
            "wo": L.dense_init(jax.random.fold_in(k3, 2), (DM, DM), jnp.float32),
            "head": L.dense_init(k4, (DM, 1), jnp.float32),
        }

    def predict(self, p: dict, toks: jnp.ndarray) -> jnp.ndarray:
        x = jnp.take(p["emb"], toks.astype(jnp.int32), axis=0)       # (N,S,DM)
        q = (x @ p["wq"]).reshape(*x.shape[:2], 4, DM // 4)
        k = (x @ p["wk"]).reshape(*x.shape[:2], 4, DM // 4)
        v = (x @ p["wv"]).reshape(*x.shape[:2], 4, DM // 4)
        att = L.attention_scores(q, k, v, causal=False, bidirectional=True)
        x = x + att.reshape(x.shape) @ p["wo"]
        return (jnp.tanh(x.mean(axis=1)) @ p["head"])[:, 0]

    def fit(self, p: dict, toks: jnp.ndarray, target: jnp.ndarray) -> dict:
        def loss(pp):
            return jnp.mean((self.predict(pp, toks) - target) ** 2)

        def step(carry, _):
            pp, m = carry
            g = jax.grad(loss)(pp)
            m = jax.tree.map(lambda mm, gg: 0.9 * mm + gg, m, g)
            pp = jax.tree.map(lambda w, mm: w - self.lr * mm, pp, m)
            return (pp, m), None

        (p, _), _ = jax.lax.scan(step, (p, jax.tree.map(jnp.zeros_like, p)),
                                 None, length=self.fit_steps)
        return p


def main():
    xc, y = make_data(768, seed=0)          # (D, N, S) token views
    xct, yt = make_data(768, seed=1)
    fam = TransformerRegressorFamily()

    t0 = time.time()
    _, avg = baselines.averaging(fam, xc, y, xct, yt)
    print(f"averaging of {D_AGENTS} stratum-transformers: test MSE {avg['test_mse']:.4f}")

    # neural agents produce highly correlated residuals -> A is near-singular
    # and raw optimal weights explode; a small Minimax delta (the paper's own
    # machinery at alpha=1) regularises the combination
    cfg = icoa.ICOAConfig(n_sweeps=5, delta=2e-4)
    _, w, hist = icoa.run(fam, cfg, xc, y, xct, yt)
    print(f"ICOA ensemble:                               test MSE {hist['test_mse'][-1]:.4f}")
    print(f"weights: {[round(float(x), 3) for x in w]}  ({time.time()-t0:.0f}s)")
    assert hist["test_mse"][-1] < avg["test_mse"]


if __name__ == "__main__":
    main()
