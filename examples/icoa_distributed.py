"""Distributed ICOA on a real device mesh (shard_map, 5 agent devices).

Each agent owns its attribute columns on its own device; residual exchange
is an `all_gather` over the "agents" mesh axis, with Minimax-Protection
compression shrinking the payload alpha-fold — the paper's trade-off as a
collective schedule. The ONLY change from the local quickstart is
`backend=shard_map` in the spec.  `trials=2` makes every point a small
Monte-Carlo mean: on shard_map `batch_fit` transparently falls back to
serial per-trial fits (the collectives are one-agent-per-device, so the
compiled vmap path is local-backend only).

    PYTHONPATH=src python examples/icoa_distributed.py
(the XLA_FLAGS line below must run before jax initialises)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=5")

import jax                                            # noqa: E402

from repro import api                                 # noqa: E402

BASE = api.ExperimentSpec(
    data=api.DataSpec(source="friedman1", n_train=2000, n_test=2000, seed=0),
    agent=api.AgentSpec(family="polynomial", options=(("degree", 4),)),
    solver=api.SolverSpec(name="icoa", n_sweeps=8),
    backend=api.BackendSpec(name="shard_map"),
)


def main():
    print(f"devices: {jax.devices()}")
    result_sets = api.sweep(BASE, {
        "solver.alpha": [1.0, 20.0, 100.0],
        "solver.delta": [0.0, 0.01, 0.02],
    }, paired=True, trials=2)
    labels = [
        "full residual exchange (O(N D^2) per sweep)",
        "5% exchange + Minimax Protection",
        "1% exchange + Minimax Protection",
    ]
    for label, rs in zip(labels, result_sets):
        tm, ts = rs.mean("test_mse"), rs.std("test_mse")
        print(f"{label:52} test MSE {tm[0]:.4f} -> {tm[-1]:.4f} ± {ts[-1]:.4f}"
              f"   wire {rs.cumulative_bytes[-1] / 1e6:.2f} MB")


if __name__ == "__main__":
    main()
