"""Distributed ICOA on a real device mesh (shard_map, 5 agent devices).

Each agent owns its attribute columns on its own device; residual exchange
is an `all_gather` over the "agents" mesh axis, with Minimax-Protection
compression shrinking the payload alpha-fold — the paper's trade-off as a
collective schedule.

    PYTHONPATH=src python examples/icoa_distributed.py
(the XLA_FLAGS line below must run before jax initialises)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=5")

import jax                                            # noqa: E402
import jax.numpy as jnp                               # noqa: E402

from repro.agents import PolynomialFamily             # noqa: E402
from repro.core import icoa                           # noqa: E402
from repro.core.distributed import run_distributed    # noqa: E402
from repro.data.friedman import make_dataset          # noqa: E402
from repro.data.partition import one_per_agent        # noqa: E402


def main():
    print(f"devices: {jax.devices()}")
    xtr, ytr, xte, yte = make_dataset(1, n_train=2000, n_test=2000, seed=0)
    groups = one_per_agent(5)
    xc = jnp.stack([xtr[:, g] for g in groups])
    xct = jnp.stack([xte[:, g] for g in groups])
    fam = PolynomialFamily(n_cols=1, degree=4)

    for alpha, delta, label in [
        (1.0, 0.0, "full residual exchange (O(N D^2) per sweep)"),
        (20.0, 0.01, "5% exchange + Minimax Protection"),
        (100.0, 0.02, "1% exchange + Minimax Protection"),
    ]:
        cfg = icoa.ICOAConfig(n_sweeps=8, alpha=alpha, delta=delta)
        _, w, hist = run_distributed(fam, cfg, xc, ytr, xct, yte)
        print(f"{label:52} test MSE {hist['test_mse'][0]:.4f} -> {hist['test_mse'][-1]:.4f}")


if __name__ == "__main__":
    main()
