"""Batched serving demo: prefill a batch of prompts, decode new tokens with
the KV/state cache (the same engine the decode_32k / long_500k dry-run
shapes lower).

    PYTHONPATH=src python examples/serve_demo.py --arch jamba-v0.1-52b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.data.lm import MarkovStream
from repro.models import build_model
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model)

    stream = MarkovStream(cfg.vocab_size, seed=0)
    import numpy as np
    toks = stream.sample(np.random.default_rng(0), args.batch, args.prompt_len)
    prompt = {"tokens": jnp.asarray(toks[:, :-1])}
    if cfg.family == "encdec":
        prompt["frames"] = jnp.zeros((args.batch, cfg.n_frames, cfg.d_model), cfg.cdtype())
    if cfg.family == "vlm":
        v = cfg.n_vision_tokens
        prompt["vision_embeds"] = jnp.zeros((args.batch, v, cfg.d_model), cfg.cdtype())
        s = prompt["tokens"].shape[1] + v
        prompt["pos_ids"] = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32),
                                             (3, args.batch, s)).copy()

    t0 = time.time()
    out, _ = engine.generate(params, prompt, max_new_tokens=args.new_tokens)
    dt = time.time() - t0
    print(f"arch={cfg.arch_id} generated {out.shape} in {dt:.1f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print("first sequence:", out[0].tolist())


if __name__ == "__main__":
    main()
