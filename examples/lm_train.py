"""End-to-end train driver (deliverable (b)): train a ~100M-param dense LM
for a few hundred steps on the synthetic Markov stream, with checkpointing.

Default is a 6-layer/640-dim (~90M with embeddings) model that fits CPU RAM;
pass --arch to train any assigned architecture's smoke config instead, or
--steps to change the budget.

    PYTHONPATH=src python examples/lm_train.py --steps 200
    PYTHONPATH=src python examples/lm_train.py --arch rwkv6-1.6b --steps 50
"""
import argparse
import dataclasses
import time

import jax

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.configs.base import ModelConfig, RunConfig
from repro.data.lm import lm_batches
from repro.models import build_model
from repro.train import init_state, make_train_step


def default_100m() -> ModelConfig:
    return ModelConfig(
        arch_id="demo-100m", family="dense",
        n_layers=6, d_model=640, n_heads=10, n_kv_heads=5,
        d_ff=1707, vocab_size=49152, remat=False, scan_block=2,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="assigned arch id (smoke config)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--data-vocab", type=int, default=1024,
                    help="concentrate the synthetic stream on this many ids "
                         "(0 = full vocab) so a short run shows learning")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True) if args.arch else default_100m()
    model = build_model(cfg)
    n_params = sum(p.size for p in jax.tree.leaves(model.param_specs()))
    print(f"arch={cfg.arch_id} params={n_params/1e6:.1f}M")

    run = RunConfig(learning_rate=args.lr, warmup_steps=20, total_steps=args.steps)
    state = init_state(model, jax.random.PRNGKey(run.seed), run)
    step_fn = jax.jit(make_train_step(model, run))
    stream = lm_batches(model, seq=args.seq, batch=args.batch, seed=0,
                        data_vocab=args.data_vocab)

    t0 = time.time()
    for i in range(args.steps):
        state, met = step_fn(state, next(stream))
        if i % 20 == 0 or i == args.steps - 1:
            toks = (i + 1) * args.batch * args.seq
            print(f"step {i:4d} loss {float(met['loss']):.4f} "
                  f"gnorm {float(met['grad_norm']):.2f} lr {float(met['lr']):.2e} "
                  f"({toks / (time.time() - t0):.0f} tok/s)", flush=True)
        if args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            path = save_checkpoint(args.ckpt_dir, i + 1, state.params)
            print(f"  checkpoint -> {path}")
    print("done.")


if __name__ == "__main__":
    main()
