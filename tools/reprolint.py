#!/usr/bin/env python
"""reprolint CLI — run the repo-specific JAX-contract lint pass.

    python tools/reprolint.py src/repro            # lint the live tree
    python tools/reprolint.py --list-rules         # rule catalog
    python tools/reprolint.py path.py --no-config  # ignore pyproject excludes

Exit status: 0 when clean, 1 when violations were found.  Excluded paths come
from `[tool.reprolint] exclude` in pyproject.toml; per-line suppression is
`# reprolint: disable=<rule>[,<rule>...]` (or `disable=all`).  DESIGN.md §9.1
documents every rule with rationale.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis import lint  # noqa: E402  (path bootstrap above)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", default=(), help="files/dirs to lint")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--no-config", action="store_true",
                    help="ignore [tool.reprolint] in pyproject.toml")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, desc in sorted(lint.RULES.items()):
            print(f"{rule}\n    {desc}")
        return 0
    if not args.paths:
        ap.error("no paths given (try: python tools/reprolint.py src/repro)")

    config = lint.LintConfig()
    if not args.no_config:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        config = lint.load_config(os.path.join(root, "pyproject.toml"))

    violations = lint.lint_paths(args.paths, config=config)
    for v in violations:
        print(v.format())
    n = len(violations)
    print(f"reprolint: {n} violation(s)" if n else "reprolint: clean")
    return 1 if n else 0


if __name__ == "__main__":
    raise SystemExit(main())
