"""CI obs smoke (DESIGN.md §13): one fit and one stream run with taps ON
and the span tracer armed, writing the event log to the given path.

Usage::

    PYTHONPATH=src python tools/obs_smoke.py [events.jsonl]

Asserts the tap surface end-to-end — `Result.metrics` / `StreamResult.
metrics` populated with the registry shapes, the eta tap matching the
recorded history, runtime-health counters moving, and a non-trivial
Prometheus scrape — then leaves the JSONL for `tools/obs_report.py` (whose
ledger cross-check is the next CI step) and uploads as an artifact.
"""
from __future__ import annotations

import sys

import numpy as np

from repro import api, obs
from repro.stream import PredictEngine


def main(argv) -> int:
    path = argv[0] if argv else "obs_events.jsonl"
    obs.configure(path, run_id="ci-smoke")
    try:
        spec = api.ExperimentSpec(
            data=api.DataSpec(n_train=150, n_test=150, seed=7),
            agent=api.AgentSpec(family="polynomial",
                                options=(("degree", 3),)),
            solver=api.SolverSpec(n_sweeps=3, eps=0.0),
            obs=obs.ObsSpec(taps=("eta", "s", "accepts")))
        res = api.fit(spec)
        d = len(spec.data.groups)
        assert res.metrics is not None
        assert res.metrics["eta"].shape == (3,)
        assert res.metrics["accepts"].shape == (3, d)
        np.testing.assert_allclose(res.metrics["eta"],
                                   np.asarray(res.history.eta[1:]),
                                   rtol=1e-5)
        print(f"fit: metrics {res.metrics.names}, "
              f"eta tap == history ({res.metrics.n_sweeps} sweeps)")

        exp = api.ExperimentSpec(
            data=api.DataSpec(source="cosine", n_train=256, n_test=64),
            solver=api.SolverSpec(name="icoa", n_sweeps=3, eps=0.0),
            obs=obs.ObsSpec(taps=("eta", "accepts")))
        sspec = api.StreamSpec(experiment=exp, window=256, chunk=64,
                               total_instances=256, resweep_every=128)
        sres = api.stream_fit(sspec)
        assert sres.metrics is not None and sres.metrics.n_sweeps > 0
        c = sres.ingestor.counters
        assert c["ingest_instances"].total == 256
        assert c["resweeps"].total == len(sres.records)
        groups = exp.data.groups
        engine = PredictEngine(sres.family, groups, n_attrs=len(groups))
        engine.update(sres.params, sres.weights)
        engine.warmup()
        engine.predict(np.zeros((8, len(groups)),
                                np.asarray(sres.weights).dtype))
        scrape = engine.metrics_text(sres.ingestor)
        assert "repro_serve_requests_total 1.0" in scrape
        assert "repro_stream_ingest_instances_total 256.0" in scrape
        print(f"stream: {sres.metrics.n_sweeps} tapped sweeps over "
              f"{len(sres.records)} resweeps; scrape "
              f"{len(scrape.splitlines())} lines")
    finally:
        obs.disable()
    print(f"event log: {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
