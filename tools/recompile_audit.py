#!/usr/bin/env python
"""Recompilation-budget CLI: check audit JSONs against the checked-in budget.

Producing an audit: run any audited process with REPRO_RECOMPILE_AUDIT set to
an output path — tests/conftest.py and benchmarks/run.py install the counter
from that env var and write `{"entry": ..., "total": N, "counts": {...}}` at
exit:

    REPRO_RECOMPILE_AUDIT=audit_tier1.json python -m pytest -x -q

Checking it (CI's budget gate; exit 1 on regression):

    python tools/recompile_audit.py check audit_tier1.json \
        --budget tools/recompile_budget.json

The budget carries ~30% headroom over measured totals: a failure means a
change introduced systematically more retraces (a broken static key, a
per-call closure), not run-to-run noise — re-measure and update the budget
only when the growth is intentional.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

_DEFAULT_BUDGET = os.path.join(os.path.dirname(__file__),
                               "recompile_budget.json")


def main(argv=None) -> int:
    from repro.analysis import recompile

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    chk = sub.add_parser("check", help="compare audit JSON(s) to the budget")
    chk.add_argument("audits", nargs="+", help="audit JSON files")
    chk.add_argument("--budget", default=_DEFAULT_BUDGET)
    args = ap.parse_args(argv)

    budget = recompile.load_budget(args.budget)
    failures = []
    for path in args.audits:
        with open(path, "r", encoding="utf-8") as fh:
            audit = json.load(fh)
        entry, total = audit["entry"], int(audit["total"])
        ceiling = budget.get(entry, {}).get("max_compiles", "∅")
        print(f"{entry}: {total} compiles (budget {ceiling})")
        failures.extend(recompile.check_budget(entry, total, budget))
    for f in failures:
        print(f"BUDGET VIOLATION: {f}", file=sys.stderr)
    if not failures:
        print("recompile audit: within budget")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
