#!/usr/bin/env bash
# Benchmark environment pinning (DESIGN.md §10.4): source this — or run a
# command through it — before any `python -m benchmarks.run` invocation so
# the numbers that land in BENCH_*.json are produced under one declared
# allocator/topology/cache regime instead of whatever the shell happened to
# have.  Usage:
#
#     source tools/bench_env.sh                       # pin this shell
#     tools/bench_env.sh python -m benchmarks.run sweep   # pin one command
#
# Everything here is override-friendly: a variable already set in the
# environment wins.

# 1) tcmalloc: glibc malloc's arena churn adds multi-percent noise to the
#    short-lived buffers of the interpret-mode Pallas paths.  Preload
#    tcmalloc when the box has it; SKIP silently when it doesn't (this
#    container does not bake it in) — benchmarks must run identically, just
#    noisier, without it.
if [ -z "${LD_PRELOAD:-}" ]; then
    for _tc in /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
               /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
               /usr/lib/libtcmalloc_minimal.so; do
        if [ -e "${_tc}" ]; then
            export LD_PRELOAD="${_tc}"
            break
        fi
    done
    unset _tc
fi

# 2) Host-device topology: the batch/transport suites shard over host
#    devices; pin the count so BENCH_batch.json is comparable across runs
#    (suites that fork workers override per-process, as CI does).  Default
#    to the core count: forcing more host devices than cores visibly slows
#    the single-device suites (measured ~2x on sweep_engines at 8 devices
#    on a 1-core box — the device framework fans work out with no cores to
#    catch it).
if [ -z "${XLA_FLAGS:-}" ]; then
    _nd="${REPRO_BENCH_DEVICES:-$(nproc 2>/dev/null || echo 1)}"
    export XLA_FLAGS="--xla_force_host_platform_device_count=${_nd}"
    unset _nd
fi

# 3) Persistent compilation cache: first-call numbers in a fresh process
#    otherwise include XLA compile time; a warm on-disk cache makes the
#    warmup call cheap and keeps the timed region pure execute.  JAX only
#    writes entries over ~1s compile time by default; threshold 0 caches
#    everything the benchmarks build.
export JAX_COMPILATION_CACHE_DIR="${JAX_COMPILATION_CACHE_DIR:-${TMPDIR:-/tmp}/repro-jax-cache}"
export JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS="${JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS:-0}"
mkdir -p "${JAX_COMPILATION_CACHE_DIR}"

# Exec mode: `tools/bench_env.sh cmd args...` runs cmd under the pinned env.
if [ "$#" -gt 0 ]; then
    exec "$@"
fi
