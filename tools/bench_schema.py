"""Validate every checked-in BENCH_*.json against the shared envelope.

Usage::

    python tools/bench_schema.py check [root]

Exit 0 when every BENCH file at the repo root parses and carries the
``{"meta": {bench, git_sha, host_cpu_count, jax_version, timestamp},
"results": ...}`` envelope (benchmarks/envelope.py); exit 1 with one line per
violation otherwise.  CI runs this in the obs smoke job, so a bench writer
that regresses to a bare payload fails the PR that broke it.

Deliberately dependency-free (no jax import): it must run in any lint
environment.
"""
from __future__ import annotations

import glob
import json
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(_HERE, ".."))

from benchmarks.envelope import validate  # noqa: E402


def check(root: str) -> int:
    paths = sorted(glob.glob(os.path.join(root, "BENCH_*.json")))
    if not paths:
        print(f"bench_schema: no BENCH_*.json under {root!r}")
        return 1
    bad = 0
    for path in paths:
        name = os.path.basename(path)
        try:
            with open(path) as fh:
                doc = json.load(fh)
            validate(doc, name)
        except (ValueError, OSError) as e:
            bad += 1
            print(f"FAIL {name}: {e}")
            continue
        meta = doc["meta"]
        legacy = " (legacy wrap)" if meta.get("legacy_wrap") else ""
        print(f"ok   {name}: bench={meta['bench']} "
              f"sha={str(meta['git_sha'])[:12]}{legacy}")
    if bad:
        print(f"bench_schema: {bad}/{len(paths)} file(s) violate the "
              f"envelope")
    return 1 if bad else 0


def main(argv) -> int:
    if not argv or argv[0] != "check":
        print(__doc__)
        return 2
    root = argv[1] if len(argv) > 1 else os.path.join(_HERE, "..")
    return check(root)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
