"""Render a run summary from an obs tracer JSONL event log.

Usage::

    python tools/obs_report.py events.jsonl

Three sections, all derived from the `repro.obs.trace` schema
(``{"ev": "span"|"event", "name": ..., "t": ..., "dur_s": ..., "tags": ...}``):

  spans    per-name count / total / mean / max wall seconds — where the run
           actually spent its host time (fit, resweep cadence, checkpoints)
  metrics  the per-record metric table from `stream.record` events (round,
           instance count, sweeps executed, eta, windowed train MSE,
           prequential MSE, re-sweep wire bytes)
  ledger   cross-check: the sum of per-record `bytes` deltas must equal the
           final record's cumulative `bytes_total` (both come from the same
           transport ledger, so a mismatch means records were dropped or the
           log mixes runs) — exit 1 on mismatch

Dependency-free (stdlib only): runs anywhere the JSONL landed, no jax
needed.
"""
from __future__ import annotations

import json
import sys
from collections import defaultdict
from typing import Any, Dict, List


def load_lines(path: str) -> List[Dict[str, Any]]:
    rows = []
    with open(path) as fh:
        for ln, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise SystemExit(f"{path}:{ln}: not JSON ({e})")
    return rows


def span_table(rows: List[Dict[str, Any]]) -> List[str]:
    agg: Dict[str, List[float]] = defaultdict(list)
    for r in rows:
        if r.get("ev") == "span":
            agg[r["name"]].append(float(r.get("dur_s", 0.0)))
    out = ["== spans ==",
           f"{'name':<24} {'count':>6} {'total_s':>10} {'mean_s':>10} "
           f"{'max_s':>10}"]
    for name in sorted(agg):
        ds = agg[name]
        out.append(f"{name:<24} {len(ds):>6} {sum(ds):>10.4f} "
                   f"{sum(ds) / len(ds):>10.4f} {max(ds):>10.4f}")
    if not agg:
        out.append("(no spans)")
    return out


def metric_table(records: List[Dict[str, Any]]) -> List[str]:
    out = ["== stream records ==",
           f"{'round':>6} {'count':>8} {'sweeps':>6} {'eta':>12} "
           f"{'train_mse':>12} {'preq_mse':>12} {'bytes':>12}"]
    for t in records:
        out.append(
            f"{t.get('round', '-'):>6} {t.get('count', '-'):>8} "
            f"{t.get('sweeps', '-'):>6} {t.get('eta', float('nan')):>12.6g} "
            f"{t.get('train_mse', float('nan')):>12.6g} "
            f"{t.get('preq_mse', float('nan')):>12.6g} "
            f"{t.get('bytes', 0):>12}")
    if not records:
        out.append("(no stream.record events)")
    return out


def ledger_check(records: List[Dict[str, Any]]) -> tuple:
    """(lines, ok): per-record byte deltas must sum to the final cumulative
    total — both sides come from the transport ledger."""
    out = ["== ledger cross-check =="]
    if not records:
        return out + ["(no records to check)"], True
    delta_sum = sum(int(t.get("bytes", 0)) for t in records)
    final_total = int(records[-1].get("bytes_total", -1))
    ok = delta_sum == final_total
    verdict = "OK" if ok else "MISMATCH"
    out.append(f"sum(per-record bytes) = {delta_sum}")
    out.append(f"final bytes_total     = {final_total}   [{verdict}]")
    if not ok:
        out.append("records were dropped or the log mixes runs — per-record "
                   "deltas and the cumulative total come from the SAME "
                   "transport ledger and must agree")
    return out, ok


def main(argv: List[str]) -> int:
    if len(argv) != 1:
        print(__doc__)
        return 2
    rows = load_lines(argv[0])
    records = [r["tags"] for r in rows
               if r.get("ev") == "event" and r.get("name") == "stream.record"]
    faults = [r["tags"] for r in rows
              if r.get("ev") == "event" and r.get("name") == "fault.crash"]
    runs = sorted({r["run"] for r in rows if "run" in r})
    print(f"{argv[0]}: {len(rows)} lines"
          + (f", run(s) {', '.join(map(str, runs))}" if runs else ""))
    for line in span_table(rows):
        print(line)
    print()
    for line in metric_table(records):
        print(line)
    if faults:
        print()
        print("== fault events ==")
        for t in faults:
            print(f"crash at round {t.get('round')} agent {t.get('agent')}")
    print()
    lines, ok = ledger_check(records)
    for line in lines:
        print(line)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
